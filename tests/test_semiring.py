"""Masked & semiring SpGEMM layer (DESIGN.md section 7).

Deliberately hypothesis-free: this coverage must run even in environments
without the optional property-testing extra.

Contracts:
  * all four semirings x {esc, heap, hash} == dense mask-after oracle, with
    masks (plain + complemented) pruned inside the accumulators;
  * boolean L@U == thresholded numeric result (semiring identity);
  * masked symbolic() returns the exact masked capacity;
  * the recipe routes masked / unsorted-boolean cases to the hash family;
  * the example's masked triangle count agrees with brute force on an
    R-MAT scale-7 graph with no dense product on the path.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (CSR, spgemm, spgemm_esc, spgemm_hash_jnp,
                        symbolic, choose_algorithm_from_stats, measure_stats,
                        masked_row_bound, resolve_semiring, SEMIRINGS)
from repro.core.recipe import SpGEMMStats
from repro.core.spgemm import symbolic_flops
from repro.data.rmat import rmat_csr, symmetrize, triangular_split

ALL_SEMIRINGS = ("plus_times", "boolean", "min_plus", "plus_first")
ALGOS = ("esc", "heap", "hash")


def _dense_oracle(a: CSR, b: CSR, sr_name: str) -> np.ndarray:
    """Independent numpy semiring product over *structural* nonzeros."""
    ad, bd = np.asarray(a.to_dense()), np.asarray(b.to_dense())
    ap, bp = ad != 0, bd != 0
    if sr_name == "plus_times":
        return ad @ bd
    if sr_name == "boolean":
        return ((ap.astype(np.float32) @ bp.astype(np.float32)) > 0) \
            .astype(np.float32)
    if sr_name == "plus_first":
        return ad @ bp.astype(np.float32)
    if sr_name == "min_plus":
        s = np.where(ap[:, :, None] & bp[None, :, :],
                     ad[:, :, None] + bd[None, :, :], np.inf)
        out = s.min(axis=1)
        return np.where(np.isinf(out), 0.0, out).astype(np.float32)
    raise AssertionError(sr_name)


def _mask_after(c: np.ndarray, mask: CSR, complement: bool) -> np.ndarray:
    md = np.asarray(mask.to_dense()) != 0
    keep = ~md if complement else md
    return np.where(keep, c, 0.0)


def _run(a, b, algo, cap, **kw):
    if algo == "heap":
        cd = _mask_after(_dense_oracle(a, b, "plus_times"),
                         kw["mask"], kw["complement_mask"]) \
            if kw.get("mask") is not None else _dense_oracle(a, b, "plus_times")
        row_cap = int(max((cd != 0).sum(axis=1))) + 1
        k_width = int(np.asarray(a.row_nnz()).max()) + 1
        return spgemm(a, b, cap, algorithm="heap", row_cap=row_cap,
                      k_width=k_width, **kw)
    return spgemm(a, b, cap, algorithm=algo, **kw)


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
@pytest.mark.parametrize("algo", ALGOS)
def test_semiring_unmasked_matches_oracle(semiring, algo):
    a = rmat_csr(5, 3, "G500", seed=3)
    b = rmat_csr(5, 3, "ER", seed=103)
    cd = _dense_oracle(a, b, semiring)
    cap = int((cd != 0).sum()) + 8
    c = _run(a, b, algo, cap, semiring=semiring)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3), \
        (semiring, algo)


@pytest.mark.parametrize("semiring", ALL_SEMIRINGS)
@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("complement", (False, True))
def test_semiring_masked_matches_mask_after_oracle(semiring, algo, complement):
    """Masked SpGEMM (pruned inside the loops) == dense mask-after oracle."""
    a = rmat_csr(5, 3, "G500", seed=11)
    b = rmat_csr(5, 3, "ER", seed=111)
    mask = rmat_csr(5, 4, "ER", seed=7)
    cd = _mask_after(_dense_oracle(a, b, semiring), mask, complement)
    cap = int((cd != 0).sum()) + 8
    c = _run(a, b, algo, cap, semiring=semiring, mask=mask,
             complement_mask=complement)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3), \
        (semiring, algo, complement)


def test_boolean_equals_thresholded_numeric():
    """Semiring identity: boolean L@U == (numeric L@U != 0) structurally."""
    a = symmetrize(rmat_csr(6, 4, "G500", seed=2))
    L, U = triangular_split(a)
    num = _dense_oracle(L, U, "plus_times")
    cap = int((num != 0).sum()) + 8
    c_bool = spgemm_esc(L, U, cap, semiring="boolean")
    got = np.asarray(c_bool.to_dense())
    assert np.array_equal(got != 0, num != 0)
    assert np.all(got[got != 0] == 1.0)


def test_symbolic_masked_capacity_exact():
    a = rmat_csr(5, 3, "G500", seed=5)
    b = rmat_csr(5, 3, "ER", seed=105)
    mask = rmat_csr(5, 4, "ER", seed=9)
    ap = np.asarray(a.to_dense()) != 0
    bp = np.asarray(b.to_dense()) != 0
    md = np.asarray(mask.to_dense()) != 0
    pat = (ap.astype(np.int32) @ bp.astype(np.int32)) > 0
    rn, indptr, flop, _ = symbolic(a, b, mask=mask)
    assert np.array_equal(np.asarray(rn), (pat & md).sum(axis=1))
    rn_c, _, _, _ = symbolic(a, b, mask=mask, complement_mask=True)
    assert np.array_equal(np.asarray(rn_c), (pat & ~md).sum(axis=1))
    # the a-priori bound dominates the exact count
    bound = np.asarray(masked_row_bound(symbolic_flops(a, b), mask))
    assert np.all(np.asarray(rn) <= bound)


def test_recipe_masked_and_unsorted_boolean_routing():
    base = dict(n_rows=1000, n_cols=1000, nnz_a=16_000, flop=256_000,
                nnz_c_est=128_000, max_row_flop=64, mean_row_nnz_a=16,
                row_skew=2.0, compression_ratio=1.5, density_ef=4.0)
    sparse_mask = SpGEMMStats(**base, mask_density=0.01)
    dense_mask = SpGEMMStats(**base, mask_density=0.9)
    # sparse mask -> hash (probe table collapses to the mask support)
    assert choose_algorithm_from_stats(sparse_mask, False,
                                       "masked") == "hash"
    # dense mask at low CR -> LxU-like regime -> heap
    assert choose_algorithm_from_stats(dense_mask, False, "masked") == "heap"
    # high CR dominates even under a dense mask
    hc = SpGEMMStats(**{**base, "compression_ratio": 8.0}, mask_density=0.9)
    assert choose_algorithm_from_stats(hc, False, "masked") == "hash"
    # unsorted boolean -> hash family regardless of use case (C8)
    s = SpGEMMStats(**base)
    assert choose_algorithm_from_stats(
        s, False, "AxA", semiring="boolean") in ("hash", "hash_vector")
    dense_ef = SpGEMMStats(**{**base, "density_ef": 16.0})
    assert choose_algorithm_from_stats(
        dense_ef, False, "AxA", semiring="boolean") == "hash_vector"
    # sorted boolean falls through to the plain table
    assert choose_algorithm_from_stats(
        s, True, "AxA", semiring="boolean") == \
        choose_algorithm_from_stats(s, True, "AxA")


def test_measure_stats_mask_density():
    a = rmat_csr(5, 3, "G500", seed=0)
    mask = rmat_csr(5, 2, "ER", seed=1)
    s = measure_stats(a, a, mask=mask)
    frac = float(mask.nnz) / (32 * 32)
    assert s.mask_density == pytest.approx(frac)
    s_c = measure_stats(a, a, mask=mask, complement_mask=True)
    assert s_c.mask_density == pytest.approx(1.0 - frac)
    assert measure_stats(a, a).mask_density == 1.0


def test_hash_jnp_contract():
    """Fallback keeps the hash contract: unsorted flag, correct values."""
    a = rmat_csr(5, 3, "G500", seed=4)
    cd = _dense_oracle(a, a, "plus_times")
    cap = int((cd != 0).sum()) + 8
    c = spgemm_hash_jnp(a, a, cap)
    assert not c.sorted_cols
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    assert int(c.nnz) == int((cd != 0).sum())
    # sort epilogue restores Table 1 sortedness
    cs = c.sort_rows()
    cols, ip = np.asarray(cs.indices), np.asarray(cs.indptr)
    for i in range(cs.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)


def test_unsorted_mask_is_canonicalized_by_dispatcher():
    """An unsorted mask (e.g. hash-family output) gives the same result as
    its sorted form -- the dispatcher re-sorts before the probes."""
    a = rmat_csr(5, 3, "G500", seed=11)
    b = rmat_csr(5, 3, "ER", seed=111)
    mask = rmat_csr(5, 4, "ER", seed=7)
    cd = _mask_after(_dense_oracle(a, b, "plus_times"), mask, False)
    cap = int((cd != 0).sum()) + 8
    for algo in ("esc", "heap"):
        c = _run(a, b, algo, cap, mask=mask.with_unsorted_flag(),
                 complement_mask=False)
        assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3), algo


def test_unsorted_mask_in_symbolic_and_shape_check():
    a = rmat_csr(5, 3, "G500", seed=11)
    b = rmat_csr(5, 3, "ER", seed=111)
    mask = rmat_csr(5, 4, "ER", seed=7)
    # symbolic canonicalizes an unsorted mask instead of asserting
    rn_sorted, _, _, _ = symbolic(a, b, mask=mask)
    rn_unsorted, _, _, _ = symbolic(a, b, mask=mask.with_unsorted_flag())
    assert np.array_equal(np.asarray(rn_sorted), np.asarray(rn_unsorted))
    # a shape-mismatched mask fails loudly, not silently
    bad = rmat_csr(4, 3, "ER", seed=1)         # 16x16 mask on a 32x32 product
    with pytest.raises(AssertionError, match="mask shape"):
        spgemm_esc(a, b, 64, mask=bad)


def test_recipe_bcsr_only_for_plain_products():
    """Block-dense stats must not recommend bcsr for semiring/masked
    requests the bcsr path would reject."""
    base = dict(n_rows=1000, n_cols=1000, nnz_a=16_000, flop=256_000,
                nnz_c_est=128_000, max_row_flop=64, mean_row_nnz_a=16,
                row_skew=2.0, compression_ratio=2.0, density_ef=16.0,
                block_density=0.5)
    plain = SpGEMMStats(**base)
    assert choose_algorithm_from_stats(plain, False, "AxA") == "bcsr"
    assert choose_algorithm_from_stats(
        plain, False, "AxA", semiring="boolean") != "bcsr"
    masked = SpGEMMStats(**base, mask_density=0.1)
    assert choose_algorithm_from_stats(masked, False, "masked") != "bcsr"
    # a fully dense mask reaches mask_density == 1.0 but is still a mask
    dense_mask = SpGEMMStats(**base, mask_density=1.0, has_mask=True)
    assert choose_algorithm_from_stats(dense_mask, False, "AxA") != "bcsr"


def test_semiring_registry():
    assert resolve_semiring("any_pair") is SEMIRINGS["boolean"]
    assert resolve_semiring(SEMIRINGS["min_plus"]).name == "min_plus"
    with pytest.raises(ValueError):
        resolve_semiring("max_times")


def test_triangle_count_scale7_no_dense_product():
    """The example's masked triangle count vs brute force at scale 7."""
    from examples.graph_analytics import triangle_count
    a = symmetrize(rmat_csr(7, 6, "G500", seed=1))
    ad = np.asarray(a.to_dense()).astype(np.int64)
    brute = int(np.trace(np.linalg.matrix_power(ad, 3)) // 6)
    assert triangle_count(a) == brute


def test_masked_bfs_agrees_with_dense_frontier():
    from examples.graph_analytics import (multi_source_bfs,
                                          multi_source_bfs_masked)
    a = symmetrize(rmat_csr(6, 6, "G500", seed=2))
    sources = [0, 5, 21]
    d_dense = np.asarray(multi_source_bfs(a, sources, n_hops=4))
    d_mask = np.asarray(multi_source_bfs_masked(a, sources, n_hops=4))
    assert np.array_equal(d_dense, d_mask)
