"""Serving engine: continuous batching, greedy exactness, cache surgery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx
from repro.serve import Engine, Request
from repro.serve.sampling import sample_logits

PCTX = single_device_ctx(remat=False, attn_impl="full")


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m",
                                  "recurrentgemma-9b"])
def test_engine_completes_all(arch):
    cfg = reduced(ARCHS[arch])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PCTX, max_batch=3, max_len=48)
    rng = np.random.default_rng(0)
    for r, plen in enumerate([4, 9, 13, 7, 5]):
        eng.add_request(Request(rid=r, prompt=rng.integers(
            0, cfg.vocab_size, size=(plen,)).astype(np.int32),
            max_new_tokens=4 + r))
    done = eng.run_to_completion()
    assert len(done) == 5
    assert sorted(len(d.out_tokens) for d in done) == [4, 5, 6, 7, 8]


def test_greedy_matches_prefill_oracle():
    cfg = reduced(ARCHS["qwen3-0.6b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, PCTX, max_batch=2, max_len=32)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32)
    eng.add_request(Request(rid=0, prompt=prompt, max_new_tokens=4))
    out = [int(t) for t in eng.run_to_completion()[0].out_tokens]
    seq = list(prompt)
    ref = []
    for _ in range(4):
        logits, _ = T.prefill(params, jnp.asarray(np.array(seq))[None], cfg,
                              PCTX)
        t = int(jnp.argmax(logits[0, 0]))
        ref.append(t)
        seq.append(t)
    assert out == ref


def test_sampling_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample_logits(key, logits, temperature=0.0)[0]) == 1
    # top-k=1 equals greedy regardless of temperature
    assert int(sample_logits(key, logits, temperature=2.0, top_k=1)[0]) == 1
    # distribution sanity under temperature
    ks = jax.random.split(key, 64)
    draws = [int(sample_logits(k, logits, temperature=1.0)[0]) for k in ks]
    assert set(draws) <= {0, 1, 2, 3}
    assert np.bincount(draws, minlength=4).argmax() == 1
