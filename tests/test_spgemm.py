"""SpGEMM algorithm equivalence: every algorithm == dense oracle.

This is the system-level contract of the paper's Table 1: all accumulators
compute the same C, differing only in sortedness and cost.
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (CSR, spgemm, spgemm_esc, spgemm_heap,
                        spmm, symbolic)
from repro.data.rmat import rmat_csr, triangular_split, tall_skinny_from, rmat_edges

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _pair(seed, scale=5, ef=3):
    a = rmat_csr(scale, ef, "G500", seed=seed)
    b = rmat_csr(scale, ef, "ER", seed=seed + 100)
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    return a, b, cd


@given(seed=st.integers(0, 30))
def test_esc_matches_oracle(seed):
    a, b, cd = _pair(seed)
    cap = int((cd != 0).sum()) + 8
    c = spgemm_esc(a, b, cap_c=cap)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    assert int(c.nnz) == int((cd != 0).sum())


@given(seed=st.integers(0, 15))
def test_heap_matches_oracle(seed):
    a, b, cd = _pair(seed)
    row_cap = int(max((cd != 0).sum(axis=1))) + 1
    k_width = int(max((np.asarray(a.to_dense()) != 0).sum(axis=1))) + 1
    c = spgemm_heap(a, b, row_cap=row_cap, k_width=k_width)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    # heap output is sorted within rows (Table 1: Sorted/Sorted)
    cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
    for i in range(c.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)


@given(seed=st.integers(0, 10))
def test_symbolic_exact(seed):
    a, b, cd = _pair(seed)
    row_nnz, indptr_c, flop, total = symbolic(a, b)
    pattern = (np.asarray(a.to_dense()) != 0).astype(np.int32) @ \
              (np.asarray(b.to_dense()) != 0).astype(np.int32)
    assert np.array_equal(np.asarray(row_nnz), (pattern > 0).sum(axis=1))
    ad = np.asarray(a.to_dense()) != 0
    bd = np.asarray(b.to_dense()) != 0
    assert int(total) == int((ad @ bd.sum(1)).sum())


def test_dispatcher_sorted_output():
    a, b, cd = _pair(0)
    cap = int((cd != 0).sum()) + 8
    c = spgemm(a, b, cap, algorithm="hash", sorted_output=True, n_bins=4)
    assert c.sorted_cols
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
    cols, ip = np.asarray(c.indices), np.asarray(c.indptr)
    for i in range(c.n_rows):
        assert np.all(np.diff(cols[ip[i]:ip[i + 1]]) > 0)


def test_dispatcher_auto():
    a, b, cd = _pair(1)
    cap = int((cd != 0).sum()) + 8
    c = spgemm(a, b, cap, algorithm="auto")
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)


@given(seed=st.integers(0, 10), k=st.sampled_from([1, 4, 16]))
def test_spmm(seed, k):
    a = rmat_csr(5, 3, "G500", seed=seed)
    x = np.random.default_rng(seed).normal(size=(32, k)).astype(np.float32)
    y = spmm(a, jnp.asarray(x))
    assert np.allclose(np.asarray(y), np.asarray(a.to_dense()) @ x,
                       atol=1e-3)


def test_triangle_counting_lxu():
    """Paper section 5.6: wedges via L @ U; triangle closure check."""
    a = rmat_csr(5, 4, "ER", seed=5)
    # symmetrize (undirected graph), remove diagonal
    ad = np.asarray(a.to_dense())
    ad = ((ad + ad.T) > 0).astype(np.float32)
    np.fill_diagonal(ad, 0.0)
    sym = CSR.from_dense(jnp.asarray(ad))
    L, U = triangular_split(sym)
    ld, ud = np.asarray(L.to_dense()), np.asarray(U.to_dense())
    wedges = ld @ ud
    cap = int((wedges != 0).sum()) + 8
    c = spgemm_esc(L, U, cap_c=cap)
    assert np.allclose(np.asarray(c.to_dense()), wedges, atol=1e-3)
    # triangle count = sum over (i,j) in A of wedges[i,j] (standard LU form)
    perm = ld + ud   # permuted adjacency
    tri = (wedges * (perm > 0)).sum() / 2
    # brute force on the permuted matrix
    p3 = np.linalg.matrix_power((perm > 0).astype(np.int64), 3)
    assert tri == np.trace(p3) / 6


def test_tall_skinny():
    """Paper section 5.5: square x tall-skinny (multi-source BFS)."""
    rows, cols = rmat_edges(5, 4, "G500", seed=2)
    a = rmat_csr(5, 4, "G500", seed=2)
    b = tall_skinny_from(rows, cols, 32, 3, seed=3)
    assert b.shape == (32, 8)
    cd = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    cap = int((cd != 0).sum()) + 8
    c = spgemm_esc(a, b, cap_c=cap, flop_cap=4096)
    assert np.allclose(np.asarray(c.to_dense()), cd, atol=1e-3)
