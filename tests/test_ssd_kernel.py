"""SSD chunk kernel sweeps vs the pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk.ops import ssd_pallas
from repro.kernels.ssd_chunk.ref import ssd_ref


def _inputs(rng, b, s, nh, hp, g, n, dtype=np.float32):
    xd = jnp.asarray(rng.normal(size=(b, s, nh, hp)).astype(dtype)) * 0.1
    la = -jnp.abs(jnp.asarray(
        rng.normal(size=(b, s, nh)).astype(np.float32))) * 0.1
    Bm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(dtype))
    Cm = jnp.asarray(rng.normal(size=(b, s, g, n)).astype(dtype))
    return xd, la, Bm, Cm


@pytest.mark.parametrize("b,s,nh,hp,g,n,chunk", [
    (1, 32, 2, 16, 1, 8, 16),     # single group
    (2, 64, 4, 16, 2, 8, 16),     # grouped heads
    (1, 48, 6, 8, 3, 16, 8),      # chunk < state, odd ratios
    (2, 32, 4, 32, 4, 8, 32),     # chunk == seq (single chunk)
])
def test_ssd_kernel_sweep(b, s, nh, hp, g, n, chunk, rng):
    xd, la, Bm, Cm = _inputs(rng, b, s, nh, hp, g, n)
    y_ref, hT_ref = ssd_ref(xd, la, Bm, Cm, chunk)
    y, hT = ssd_pallas(xd, la, Bm, Cm, chunk)
    assert float(jnp.abs(y - y_ref).max()) < 1e-4
    assert float(jnp.abs(jnp.swapaxes(hT, -1, -2) - hT_ref).max()) < 1e-4


def test_ssd_kernel_state_carry_across_many_chunks(rng):
    """Long sequence: the grid-carried VMEM state must match the scan."""
    xd, la, Bm, Cm = _inputs(rng, 1, 128, 2, 16, 1, 8)
    y_ref, hT_ref = ssd_ref(xd, la, Bm, Cm, 16)
    y, hT = ssd_pallas(xd, la, Bm, Cm, 16)   # 8 chunks
    assert float(jnp.abs(y - y_ref).max()) < 1e-4
    assert float(jnp.abs(jnp.swapaxes(hT, -1, -2) - hT_ref).max()) < 1e-4


def test_ssd_kernel_strong_decay(rng):
    """Strong decay (a ~ 0): output reduces to the intra-chunk term."""
    xd, la, Bm, Cm = _inputs(rng, 1, 32, 2, 8, 1, 4)
    la = jnp.full_like(la, -50.0)   # exp ~ 0 across steps
    y, hT = ssd_pallas(xd, la, Bm, Cm, 8)
    y_ref, _ = ssd_ref(xd, la, Bm, Cm, 8)
    assert float(jnp.abs(y - y_ref).max()) < 1e-4
    assert bool(jnp.all(jnp.isfinite(y)))
