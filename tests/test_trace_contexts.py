"""Cross-context differential layer for the Pallas hash path.

One structure-frozen plan must produce the same answer no matter which
trace context executes it: eagerly, under ``jit``, under ``vmap`` over a
member value fleet (the ``BatchedPlan`` class-program shape), and inside
``shard_map`` SPMD bodies (the ``DistributedPlan`` executor shape).  The
trace-time dispatch counters (``repro.kernels.spgemm_hash.ops
.KERNEL_CALLS``) prove the real Pallas kernels -- not the retired jnp
twin dispatch -- are what stages into each traced program.

Values are dyadic (``tests/_fuzz.py``) so fp32 arithmetic is exact and
every comparison is bitwise even against per-product-rounding oracles:
the kernel accumulates with the backend's FMA (one rounding per probe;
see ``repro.kernels.spgemm_hash.ops`` for the rounding contract), which
is indistinguishable from separate rounding when products and sums are
exactly representable.

The 8-device ``shard_map`` equivalence runs as a subprocess (XLA's host
device count must be set before jax initializes), reusing the harness of
``tests/test_distributed.py``.
"""
import dataclasses
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (clear_plan_cache, plan_batch, plan_cache_stats,  # noqa: E402
                        plan_spgemm)
from repro.core.distributed import (plan_spgemm_1d, shard_csr_rows,  # noqa: E402
                                    unshard_rows)
from repro.core.formats import bcsr_to_csr, csr_to_bcsr  # noqa: E402
from repro.kernels.spgemm_hash import ops as hash_ops  # noqa: E402
from repro.kernels.spgemm_bcsr import ops as bcsr_ops  # noqa: E402
from repro.kernels.spgemm_bcsr import ref as bcsr_ref  # noqa: E402
from benchmarks.common import counted  # noqa: E402
from _fuzz import (block_clustered_dense, csr_of as _csr,  # noqa: E402
                   member_value_fleet, rand_dense as _rand_dense,
                   run_planned_hash_in_context)
from test_distributed import _run  # noqa: E402

sp = pytest.importorskip("scipy.sparse")


@pytest.fixture(scope="module", autouse=True)
def _fresh_executable_caches():
    """Drop jit executables accumulated by the ~290 suites that run
    before this module in a full tier-1 pass.  XLA's CPU LLVM JIT has
    been observed to segfault compiling a fresh program signature at the
    tail of that accumulation (inside ``backend_compile``, upstream
    jaxlib issue, not reachable from Python); starting this module from
    an empty compilation cache keeps the full-suite run off that edge
    and costs only this module's own recompiles."""
    jax.clear_caches()


def _scipy_dense(ad: np.ndarray, bd: np.ndarray) -> np.ndarray:
    return np.asarray((sp.csr_matrix(ad) @ sp.csr_matrix(bd)).todense())


def _case(m=8, k=6, n=9, d=0.4, seed=20, n_members=3):
    ad = _rand_dense(m, k, d, seed)
    bd = _rand_dense(k, n, d, seed + 1)
    vals = member_value_fleet(ad, n_members, seed + 2)
    return ad, bd, vals


def _member_dense(ad, vals_e):
    d = ad.copy()
    r, c = np.nonzero(ad)
    d[r, c] = vals_e[:len(r)]
    return d


# ---------------------------------------------------------------------------
# Tentpole: the same plan, eager / jit / vmap / shard_map, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vector", (False, True))
def test_planned_hash_eager_jit_vmap_bitwise(vector):
    """One frozen plan; eager, jit and vmap executions agree bitwise with
    each other, with the jnp reference oracle, and with scipy -- and the
    batched-grid kernel (not the twin) is what the vmap trace stages."""
    ad, bd, vals = _case()
    a, b = _csr(ad), _csr(bd)
    algo = "hash_vector" if vector else "hash"
    plan = plan_spgemm(a, b, algorithm=algo)
    twin = plan_spgemm(a, b, algorithm="hash_jnp", cache=False)

    def one(v):
        return plan.execute(dataclasses.replace(a, data=v), b).to_dense()

    pad = a.cap - vals.shape[1]
    vstack = jnp.asarray(np.concatenate(
        [vals, np.zeros((vals.shape[0], pad), np.float32)], axis=1)
        if pad else vals)

    eager = [np.asarray(one(vstack[e])) for e in range(len(vals))]
    jitted = [np.asarray(jax.jit(one)(vstack[e])) for e in range(len(vals))]

    hash_ops.reset_kernel_calls()
    vmapped = np.asarray(jax.vmap(one)(vstack))
    assert hash_ops.kernel_call_counts()["batched_numeric"] > 0

    for e in range(len(vals)):
        ad_e = _member_dense(ad, vals[e])
        oracle = _scipy_dense(ad_e, bd)
        ref = np.asarray(
            twin.execute(_csr(ad_e, cap=a.cap), b).to_dense())
        assert np.array_equal(eager[e], oracle), e
        assert np.array_equal(eager[e], ref), e
        assert np.array_equal(jitted[e], eager[e]), e
        assert np.array_equal(vmapped[e], eager[e]), e


@pytest.mark.parametrize("context", ("vmap", "shard_map", "both"))
def test_shared_runner_contexts_bitwise(context):
    """The shared trace-context runner (also the hypothesis property
    layer's executor) matches scipy per member, with the right kernel
    counter firing for the context."""
    ad, bd, vals = _case(m=5, k=8, n=7, seed=30)
    a, b = _csr(ad), _csr(bd)
    dense, counts = run_planned_hash_in_context(a, b, vals, context)
    for e in range(len(vals)):
        oracle = _scipy_dense(_member_dense(ad, vals[e]), bd)
        assert np.array_equal(dense[e], oracle), (context, e)
    if context in ("vmap", "both"):
        assert counts["batched_numeric"] > 0, counts
    else:
        assert counts["numeric"] > 0, counts


# ---------------------------------------------------------------------------
# Planned BCSR: eager / jit / vmap, kernel counter-verified, twin bitwise
# ---------------------------------------------------------------------------

def test_planned_bcsr_eager_jit_vmap_bitwise():
    """One frozen block plan; eager, jit and vmap executions of the
    Pallas block kernel (dispatch counter-verified -- never the jnp twin)
    agree bitwise with each other, with the jnp reference twin, and with
    the CSR planned hash path after ``bcsr_to_csr``."""
    from repro.core import plan_bcsr

    ad = block_clustered_dense(4, 3, 4, 4, 0.6, seed=50)
    bd = block_clustered_dense(3, 4, 4, 4, 0.6, seed=51)
    ab = csr_to_bcsr(_csr(ad), (4, 4))
    bb = csr_to_bcsr(_csr(bd), (4, 4))
    plan = plan_bcsr(ab, bb, cache=False)

    # eager: numeric-only Pallas dispatch, bitwise vs twin + CSR path
    bcsr_ops.reset_kernel_calls()
    eager = np.asarray(plan.execute(ab, bb).to_dense())
    counts = bcsr_ops.kernel_call_counts()
    assert counts["numeric"] == 1 and counts["symbolic"] == 0, counts
    assert np.array_equal(eager, np.asarray(bcsr_ref.numeric_ref(ab, bb)))
    assert np.array_equal(eager, _scipy_dense(ad, bd))
    a_csr, b_csr = bcsr_to_csr(ab), bcsr_to_csr(bb)
    csr_plan = plan_spgemm(a_csr, b_csr, algorithm="hash", cache=False)
    assert np.array_equal(
        eager, np.asarray(csr_plan.execute(a_csr, b_csr).to_dense()))

    def one(blk):
        return plan.execute(dataclasses.replace(ab, blocks=blk),
                            bb).to_dense()

    # jit: same program, same counter, bitwise
    bcsr_ops.reset_kernel_calls()
    jitted = np.asarray(jax.jit(one)(ab.blocks))
    assert bcsr_ops.kernel_call_counts()["numeric"] == 1
    assert np.array_equal(jitted, eager)

    # vmap over a member block-value fleet on A's frozen block pattern:
    # the batched-grid kernel (custom_vmap rule), never the twin
    rng = np.random.default_rng(52)
    vstack = rng.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                        size=(3,) + ab.blocks.shape)
    vstack *= (np.asarray(ab.blocks) != 0)      # keep the frozen pattern
    vstack[0] = np.asarray(ab.blocks)
    bcsr_ops.reset_kernel_calls()
    vmapped = np.asarray(jax.vmap(one)(jnp.asarray(vstack)))
    counts = bcsr_ops.kernel_call_counts()
    assert counts["batched_numeric"] == 1 and counts["symbolic"] == 0, \
        counts
    assert np.array_equal(vmapped[0], eager)
    for e in range(1, len(vstack)):
        member = dataclasses.replace(ab, blocks=jnp.asarray(vstack[e]))
        assert np.array_equal(
            vmapped[e], np.asarray(bcsr_ref.numeric_ref(member, bb))), e


# ---------------------------------------------------------------------------
# BatchedPlan class programs dispatch the real kernel under vmap
# ---------------------------------------------------------------------------

def test_batched_plan_class_program_runs_pallas_bitwise():
    """A dyadic fleet plans to the hash family, its class programs stage
    the batched-grid Pallas kernel (never the jnp twin), and every member
    is bitwise-equal to the per-product planned path, the twin oracle,
    and scipy."""
    shapes = [(8, 6, 9), (8, 6, 9), (5, 7, 4), (8, 6, 9), (5, 7, 4)]
    pairs, denses = [], []
    for i, (m, k, n) in enumerate(shapes):
        ad = _rand_dense(m, k, 0.45, seed=100 + 2 * i)
        bd = _rand_dense(k, n, 0.45, seed=101 + 2 * i)
        pairs.append((_csr(ad), _csr(bd)))
        denses.append((ad, bd))
    plan = plan_batch(pairs, algorithm="hash")
    assert set(plan.algorithms) == {"hash"}

    twin_calls: dict = {}
    restore = counted("repro.core.batch", "spgemm_hash_jnp", twin_calls)
    hash_ops.reset_kernel_calls()
    try:
        outs = plan.execute(pairs)
    finally:
        restore()
    assert hash_ops.kernel_call_counts()["batched_numeric"] > 0
    assert not twin_calls, f"jnp twin dispatched in a class program: " \
        f"{twin_calls}"

    for (a, b), (ad, bd), c in zip(pairs, denses, outs):
        got = np.asarray(c.to_dense())
        per = plan_spgemm(a, b, algorithm="hash", cache=False).execute(a, b)
        ref = plan_spgemm(a, b, algorithm="hash_jnp",
                          cache=False).execute(a, b)
        assert np.array_equal(got, np.asarray(per.to_dense()))
        assert np.array_equal(got, np.asarray(ref.to_dense()))
        assert np.array_equal(got, _scipy_dense(ad, bd))


# ---------------------------------------------------------------------------
# Plan cache: one structure, three plan kinds, identical numerics
# ---------------------------------------------------------------------------

def test_plan_cache_kinds_across_contexts():
    """The same product structure planned eagerly, as a one-member fleet,
    and as a sharded plan lands in three distinct cache kinds; all three
    executions run the Pallas kernel and agree bitwise."""
    ad = _rand_dense(8, 8, 0.5, seed=200)
    bd = _rand_dense(8, 8, 0.5, seed=201)
    a, b = _csr(ad), _csr(bd)
    clear_plan_cache()

    p_single = plan_spgemm(a, b, algorithm="hash")
    p_batch = plan_batch([(a, b)], algorithm="hash")
    a_sh = shard_csr_rows(a, 2, b=b)
    p_dist = plan_spgemm_1d(a_sh, b, algorithm="hash")

    kinds = plan_cache_stats()["kinds"]
    assert kinds["spgemm"] >= 1 and kinds["batch"] >= 1 \
        and kinds["dist_1d"] >= 1, kinds

    hash_ops.reset_kernel_calls()
    c_single = np.asarray(p_single.execute(a, b).to_dense())
    assert hash_ops.kernel_call_counts()["numeric"] > 0

    hash_ops.reset_kernel_calls()
    c_batch = np.asarray(p_batch.execute([(a, b)])[0].to_dense())
    assert hash_ops.kernel_call_counts()["batched_numeric"] > 0

    hash_ops.reset_kernel_calls()
    c_dist = np.asarray(unshard_rows(
        p_dist.execute_shards_host(a_sh, b)).to_dense())
    assert hash_ops.kernel_call_counts()["numeric"] > 0

    oracle = _scipy_dense(ad, bd)
    assert np.array_equal(c_single, oracle)
    assert np.array_equal(c_batch, oracle)
    assert np.array_equal(c_dist, oracle)


# ---------------------------------------------------------------------------
# shard_map on 8 host devices (subprocess: device count precedes jax init)
# ---------------------------------------------------------------------------

def test_shard_map_8dev_pallas_bitwise():
    """A planned 1D distributed product on an 8-device mesh stages the
    Pallas numeric kernel inside the shard_map body (counter proof, twin
    never dispatched) and is bitwise-equal to the single-node planned
    product, the jnp twin oracle, and the dense reference."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import plan_spgemm
from repro.core.distributed import shard_csr_rows, plan_spgemm_1d, \
    unshard_rows
from repro.core.formats import CSR
from repro.kernels.spgemm_hash import ops as hash_ops
import importlib
assert len(jax.devices()) == 8

rng = np.random.default_rng(7)
def dyadic(m, n, d, seed):
    r = np.random.default_rng(seed)
    dd = r.choice(np.array([0.5, 1.0, 1.5, 2.0], np.float32), size=(m, n))
    return np.where(r.random((m, n)) < d, dd, 0.0).astype(np.float32)
ad = dyadic(64, 48, 0.12, 1)
bd = dyadic(48, 56, 0.12, 2)
r, c = np.nonzero(ad)
a = CSR.from_numpy_coo(r, c, ad[r, c], ad.shape)
r, c = np.nonzero(bd)
b = CSR.from_numpy_coo(r, c, bd[r, c], bd.shape)

mesh = Mesh(np.array(jax.devices()), ("data",))
a_sh = shard_csr_rows(a, 8, b=b)
dp = plan_spgemm_1d(a_sh, b, algorithm="hash")

# twin-never-dispatched spy on the module global the hash fallback uses
spgemm_mod = importlib.import_module("repro.core.spgemm")
twin_calls = {"n": 0}
orig_twin = spgemm_mod.spgemm_hash_jnp
def spy(*args, **kw):
    twin_calls["n"] += 1
    return orig_twin(*args, **kw)
spgemm_mod.spgemm_hash_jnp = spy
hash_ops.reset_kernel_calls()
try:
    c_sh = unshard_rows(dp.execute(mesh, a_sh, b))
finally:
    spgemm_mod.spgemm_hash_jnp = orig_twin
counts = hash_ops.kernel_call_counts()
assert counts["numeric"] > 0, counts       # Pallas staged in the SPMD body
assert twin_calls["n"] == 0, "jnp twin dispatched inside the executor"

got = np.asarray(c_sh.to_dense())
ref_pallas = plan_spgemm(a, b, algorithm="hash").execute(a, b)
ref_twin = plan_spgemm(a, b, algorithm="hash_jnp", cache=False)\
    .execute(a, b)
assert np.array_equal(got, np.asarray(ref_pallas.to_dense()))
assert np.array_equal(got, np.asarray(ref_twin.to_dense()))
assert np.array_equal(got, ad.astype(np.float64) @ bd.astype(np.float64))
print("OK")
""", n_dev=8)
