"""Training substrate: optimizer, microbatching, compression, loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.parallel.sharding import single_device_ctx
from repro.train import optimizer as opt
from repro.train import step as step_lib
from repro.train import loop as loop_lib
from repro.data.lm_synthetic import DataPipeline

CFG = reduced(ARCHS["qwen3-0.6b"], d_model=64, vocab=64)
PCTX = single_device_ctx(remat=False, attn_impl="full")
OCFG = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)


def test_int8_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(37, 5))
                    .astype(np.float32))
    q = opt._quantize(x)
    y = opt._dequantize(q)
    assert y.shape == x.shape
    # per-block absmax int8: relative error bounded by ~1/127 of block max
    err = np.abs(np.asarray(y - x))
    assert err.max() <= float(jnp.abs(x).max()) / 127 + 1e-6


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_optimizer_state_dtypes(state_dtype):
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=1, state_dtype=state_dtype)
    params_f32 = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    params, st = opt.init(params_f32, ocfg)
    assert params["w"].dtype == jnp.bfloat16  # working params (iter 8)
    grads = {"w": jnp.full((8, 8), 0.1), "b": jnp.full((8,), 0.1)}
    p2, st2, m = opt.update(grads, st, params, ocfg)
    assert int(st2.step) == 1
    # the f32 master always moves; the bf16 working copy moves when the
    # update exceeds a bf16 ulp (warmup_steps=1 makes it large enough)
    assert float(jnp.abs(st2.master["w"] - 1.0).max()) > 0
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0
    assert bool(jnp.isfinite(m["grad_norm"]))


def test_microbatch_equivalence():
    """2-microbatch accumulated grads == full-batch grads.  Uses the pure
    f32 parameter path so the equality is exact (bf16 working params round
    each microbatch's cotangents, which Adam's step-1 sign behaviour then
    amplifies -- not an accumulation bug)."""
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50,
                           param_dtype="float32")
    key = jax.random.PRNGKey(0)
    data = DataPipeline(CFG, 4, 32)
    batch = data.batch(0)
    s1 = step_lib.init_state(key, CFG, ocfg)
    s2 = step_lib.init_state(key, CFG, ocfg)
    t1 = step_lib.make_train_step(CFG, PCTX, ocfg, n_microbatches=1)
    t2 = step_lib.make_train_step(CFG, PCTX, ocfg, n_microbatches=2)
    s1b, m1 = jax.jit(t1)(s1, batch)
    s2b, m2 = jax.jit(t2)(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)))
    assert d < 1e-4


@pytest.mark.parametrize("compression", ["bf16", "int8_ef"])
def test_compressed_training_converges(compression):
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40)
    lcfg = loop_lib.LoopConfig(total_steps=25, ckpt_every=1000, log_every=5,
                               global_batch=4, seq_len=32,
                               grad_compression=compression)
    _, hist = loop_lib.run(CFG, PCTX, ocfg, lcfg)
    assert hist[-1]["loss"] < hist[0]["loss"], compression


def test_error_feedback_buffer_updates():
    key = jax.random.PRNGKey(1)
    st = step_lib.init_state(key, CFG, OCFG, grad_compression="int8_ef")
    data = DataPipeline(CFG, 4, 32)
    t = step_lib.make_train_step(CFG, PCTX, OCFG,
                                 grad_compression="int8_ef")
    st2, _ = jax.jit(t)(st, data.batch(0))
    ef_norm = sum(float(jnp.abs(x.astype(jnp.float32)).sum())
                  for x in jax.tree.leaves(st2.ef))
    assert ef_norm > 0, "EF buffer should hold quantization residual"


def test_lr_schedule():
    ocfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.lr_schedule(ocfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt.lr_schedule(ocfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt.lr_schedule(ocfg, jnp.int32(100))) == pytest.approx(0.1)


def test_data_pipeline_determinism():
    d1 = DataPipeline(CFG, 4, 16, seed=3)
    d2 = DataPipeline(CFG, 4, 16, seed=3)
    b1, b2 = d1.batch(7), d2.batch(7)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = d1.batch(8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
