"""The static contract checker, turned on itself.

Four suites:

* **Seeded lint violations** -- every layer-2 rule is demonstrated by a
  deliberately-broken construct in ``tests/_bad_kernels.py`` (linted
  under a pretend in-tree path so path-scoped rules apply); a rule that
  stops firing on its seeded line is a rule that rotted.
* **Waivers** -- a ``# verify: allow(rule)`` comment downgrades the
  violation to a reported waiver, on the line or on the enclosing def.
* **Interval engine** -- a Pallas kernel with a provably in-bounds
  store passes; an out-of-bounds twin is flagged as a violation.
* **VC differential (fuzz satellite)** -- ``_fuzz.perturb_plan`` twins
  (capacity below nnz_c, halved hash tables) are rejected by
  :func:`repro.verify.check_plan_vcs` while the untouched plan passes.

The live-tree gate (``python -m repro.verify --all``) runs in CI; here
``test_repo_surface_is_lint_clean`` pins the layer-2 half so a plain
pytest run also catches regressions.
"""
import pathlib
import re

import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from _fuzz import PLAN_PERTURBATIONS, csr_of, perturb_plan, rand_dense
from repro.core import plan_spgemm
from repro.verify import (JaxprAnalyzer, check_plan_vcs,
                          run_layer2, verify_spgemm)
from repro.verify.intervals import Ival, VIOLATION
from repro.verify.lint import lint_source

ROOT = pathlib.Path(__file__).resolve().parents[1]
BAD_PATH = ROOT / "tests" / "_bad_kernels.py"
#: pretend in-tree location: inside src/repro, core/, and kernels/, so
#: every path-scoped rule is in scope for the seeded fixture
FAKE_PATH = "src/repro/core/kernels/_bad.py"


def _seeded_lines():
    """rule name -> sorted list of ``# BAD:`` line numbers in the fixture."""
    marks = {}
    for lineno, text in enumerate(BAD_PATH.read_text().splitlines(), 1):
        m = re.search(r"#\s*BAD:\s*([a-z-]+)", text)
        if m:
            marks.setdefault(m.group(1), []).append(lineno)
    return marks


def test_every_rule_has_a_seeded_violation():
    import repro.verify.rules  # noqa: F401  (registers the rule set)
    from repro.verify.lint import rule_names
    marks = _seeded_lines()
    assert set(marks) == set(rule_names()), \
        "every registered rule needs a # BAD: line in _bad_kernels.py"
    assert len(marks) >= 6


def test_seeded_violations_all_fire_on_their_lines():
    violations, waivers = lint_source(BAD_PATH.read_text(), FAKE_PATH)
    assert not waivers
    got = {}
    for v in violations:
        got.setdefault(v.rule, set()).add(v.line)
    for rule, lines in _seeded_lines().items():
        assert rule in got, f"rule {rule} never fired on the fixture"
        assert got[rule] == set(lines), \
            f"{rule}: fired on {sorted(got[rule])}, seeded {lines}"
    # and nothing fired on an unmarked line
    marked = {ln for lines in _seeded_lines().values() for ln in lines}
    stray = {(v.rule, v.line) for v in violations if v.line not in marked}
    assert not stray, f"unseeded findings: {stray}"


def test_waiver_comment_downgrades_to_reported_waiver():
    src = ("def f(c):\n"
           "    return c.to_dense()  # verify: allow(no-densify)\n")
    violations, waivers = lint_source(src, FAKE_PATH, ["no-densify"])
    assert not violations
    assert [w.rule for w in waivers] == ["no-densify"]

    # a waiver on the enclosing def line covers the whole body
    src = ("def f(c):  # verify: allow(no-densify)\n"
           "    return c.to_dense()\n")
    violations, waivers = lint_source(src, FAKE_PATH, ["no-densify"])
    assert not violations and len(waivers) == 1

    # but a waiver for a *different* rule suppresses nothing
    src = ("def f(c):\n"
           "    return c.to_dense()  # verify: allow(counter-reset)\n")
    violations, _ = lint_source(src, FAKE_PATH, ["no-densify"])
    assert len(violations) == 1


def test_bad_fixture_is_excluded_from_the_ci_surface():
    from repro.verify.lint import default_paths
    assert not any(p.endswith("_bad_kernels.py")
                   for p in default_paths(str(ROOT)))


def test_repo_surface_is_lint_clean():
    violations, _waivers, n_files = run_layer2(str(ROOT))
    assert n_files > 50
    assert violations == [], "\n".join(str(v) for v in violations)


# ---------------------------------------------------------------------------
# interval engine on hand-built Pallas kernels
# ---------------------------------------------------------------------------

def _analyze_kernel(kernel, grid, out_len):
    fn = pl.pallas_call(
        kernel, grid=(grid,),
        out_shape=jax.ShapeDtypeStruct((out_len,), jnp.float32))
    cj = jax.make_jaxpr(fn)()
    analyzer = JaxprAnalyzer()
    analyzer.analyze(cj, [])
    return analyzer


def test_interval_engine_proves_in_bounds_store():
    def ok_kernel(o_ref):
        i = pl.program_id(0)
        o_ref[i] = 1.0

    analyzer = _analyze_kernel(ok_kernel, grid=4, out_len=8)
    assert not [s for s in analyzer.sites if s.status == VIOLATION]
    assert any(s.status == "proved" for s in analyzer.sites)


def test_interval_engine_flags_out_of_bounds_store():
    def oob_kernel(o_ref):
        i = pl.program_id(0)
        o_ref[i + 8] = 1.0      # i in [0, 3] -> index in [8, 11], len 8

    analyzer = _analyze_kernel(oob_kernel, grid=4, out_len=8)
    bad = [s for s in analyzer.sites if s.status == VIOLATION]
    assert bad, "out-of-bounds store must be a violation"
    assert bad[0].index == (8, 11)


def test_ival_arithmetic_basics():
    a, b = Ival(0, 3), Ival(2, 5)
    assert a.join(b).lo == 0 and a.join(b).hi == 5
    assert a.within(0, 3) and not b.within(0, 3)


# ---------------------------------------------------------------------------
# VC differential: perturbed frozen plans must be rejected (fuzz satellite)
# ---------------------------------------------------------------------------

def _hash_plan():
    a = csr_of(rand_dense(12, 10, 0.4, 11))
    b = csr_of(rand_dense(10, 9, 0.4, 12))
    return plan_spgemm(a, b, algorithm="hash", cache=False), a, b


@pytest.mark.parametrize("which", PLAN_PERTURBATIONS)
def test_perturbed_plan_rejected_untouched_passes(which):
    plan, _a, _b = _hash_plan()
    assert all(vc.ok for vc in check_plan_vcs(plan)), \
        "the untouched plan must verify clean"
    bad = perturb_plan(plan, which)
    failed = [vc.name for vc in check_plan_vcs(bad) if not vc.ok]
    assert failed, f"perturbation {which!r} was not rejected"
    # perturb_plan returns a twin; the original still verifies
    assert all(vc.ok for vc in check_plan_vcs(plan))


def test_cap_perturbation_fails_capacity_vcs():
    plan, _a, _b = _hash_plan()
    failed = {vc.name for vc in check_plan_vcs(perturb_plan(plan, "cap_c"))
              if not vc.ok}
    assert {"nnz-consistent", "store-capacity"} & failed


def test_verify_spgemm_end_to_end_clean():
    plan, a, b = _hash_plan()
    case = verify_spgemm(plan, a, b)
    assert case.ok, (case.violations,
                     [vc for vc in case.vcs if not vc.ok], case.budget)
    assert not case.violations
    assert case.site_counts.get("proved", 0) > 0
    assert case.budget["got"]["pallas_call"] == 1


def test_verify_spgemm_catches_perturbed_schedule():
    plan, a, b = _hash_plan()
    case = verify_spgemm(perturb_plan(plan, "bin_tsize"), a, b,
                         name="spgemm/seeded-bad")
    assert not case.ok
    assert any(not vc.ok for vc in case.vcs)
