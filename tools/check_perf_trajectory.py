#!/usr/bin/env python
"""Gate fresh perf-trajectory runs against the committed baseline.

Usage:
    python tools/check_perf_trajectory.py FRESH.json [FRESH2.json ...] \
        BASELINE.json [--threshold 1.3] [--min-us 50] [--selftest]

The *last* positional is the baseline; every earlier one is an
independent fresh run.  Rows are matched by exact ``name``.  The raw
per-row ratio ``fresh/baseline`` confounds real regressions with
machine speed (CI runners differ run to run), so the gate normalizes:
each row's ratio is divided by the **median ratio across all matched
rows of its run**, and a row fails only when that normalized ratio
exceeds the threshold.  A uniform 2x slower machine has median 2x and
every normalized ratio 1.0 -- passes; a single kernel regressing 2x on
an otherwise stable run has median ~1.0 and normalized ratio ~2.0 --
fails.  With several fresh runs, a row must regress in **every** run to
fail -- a real regression reproduces, scheduler noise does not.  Both
timings already come from median-of-3 (``benchmarks.common.bench``),
and rows faster than ``--min-us`` in the *baseline* are skipped as pure
dispatch noise.

Unmatched rows (suites added or removed since the baseline) are
reported but never fail the gate: the baseline is regenerated in the
same PR that changes the suite.

``--selftest`` runs the gate against synthetic documents -- a clean run
must pass and a run with one injected 2x row must fail -- so CI proves
the gate can actually fire before trusting its green.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 1:
        raise SystemExit(f"{path}: unknown trajectory schema "
                         f"{doc.get('schema')!r}")
    return {r["name"]: float(r["us_per_call"]) for r in doc["rows"]}


def check(fresh: dict, base: dict, threshold: float = 1.3,
          min_us: float = 50.0) -> tuple[list, list]:
    """Returns ``(failures, report_lines)``; empty failures = pass."""
    matched = [(name, fresh[name], base[name]) for name in sorted(base)
               if name in fresh and base[name] >= min_us]
    report = [f"matched {len(matched)} rows "
              f"(baseline has {len(base)}, fresh has {len(fresh)}; "
              f"min-us {min_us})"]
    for name in sorted(set(base) ^ set(fresh)):
        side = "baseline-only" if name in base else "fresh-only"
        report.append(f"  unmatched ({side}): {name}")
    if not matched:
        report.append("no matched rows above the noise floor; passing")
        return [], report
    ratios = {name: f / b for name, f, b in matched}
    med = statistics.median(ratios.values())
    report.append(f"median fresh/baseline ratio {med:.3f} "
                  "(machine-speed normalizer)")
    failures = []
    for name, f, b in sorted(matched, key=lambda r: -ratios[r[0]] / med):
        norm = ratios[name] / max(med, 1e-12)
        line = (f"  {name}: {b:.0f}us -> {f:.0f}us "
                f"(raw {ratios[name]:.2f}x, normalized {norm:.2f}x)")
        if norm > threshold:
            failures.append(name)
            line += f"  REGRESSION > {threshold}x"
        report.append(line)
    return failures, report


def check_runs(fresh_runs: list, base: dict, threshold: float = 1.3,
               min_us: float = 50.0) -> tuple[list, list]:
    """Gate several independent fresh runs: a row fails only if it
    regresses past the threshold in *every* run (real regressions
    reproduce; scheduler noise does not)."""
    per_run = [check(fresh, base, threshold, min_us)
               for fresh in fresh_runs]
    report: list = []
    for i, (_, rep) in enumerate(per_run, 1):
        report.append(f"--- fresh run {i}/{len(per_run)} ---")
        report.extend(rep)
    failure_sets = [set(fails) for fails, _ in per_run]
    reproduced = sorted(set.intersection(*failure_sets))
    flaky = sorted(set.union(*failure_sets) - set(reproduced))
    if flaky:
        report.append(f"not reproduced across all runs (ignored): "
                      f"{', '.join(flaky)}")
    return reproduced, report


def selftest(threshold: float, min_us: float) -> int:
    base = {f"suite,row{i}": 1000.0 + 10 * i for i in range(8)}
    # clean run on a uniformly 1.7x slower machine: must pass
    clean = {k: v * 1.7 for k, v in base.items()}
    fails, _ = check(clean, base, threshold, min_us)
    assert not fails, f"selftest: clean slower-machine run failed: {fails}"
    # same run with one 2x-regressed row: must fail, and only that row
    regressed = dict(clean)
    regressed["suite,row3"] *= 2.0
    fails, _ = check(regressed, base, threshold, min_us)
    assert fails == ["suite,row3"], \
        f"selftest: injected regression not caught (got {fails})"
    # sub-noise-floor rows never fire
    tiny_base = {"suite,tiny": min_us / 2}
    fails, _ = check({"suite,tiny": min_us * 100}, tiny_base,
                     threshold, min_us)
    assert not fails, "selftest: noise-floor row fired"
    # multi-run semantics: a regression present in every run fires...
    fails, _ = check_runs([regressed, regressed], base, threshold, min_us)
    assert fails == ["suite,row3"], \
        f"selftest: reproduced regression not caught (got {fails})"
    # ...one present in only one run (scheduler noise) does not
    fails, _ = check_runs([regressed, clean], base, threshold, min_us)
    assert not fails, f"selftest: non-reproduced noise fired: {fails}"
    print("check_perf_trajectory selftest: OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", metavar="JSON",
                    help="one or more FRESH runs followed by the "
                         "BASELINE (last path)")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="max normalized slowdown per matched row")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip rows under this baseline time (noise)")
    ap.add_argument("--selftest", action="store_true",
                    help="prove the gate fires on an injected regression")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest(args.threshold, args.min_us)
    if len(args.files) < 2:
        ap.error("need FRESH... BASELINE json paths (or --selftest)")
    *fresh_paths, base_path = args.files
    failures, report = check_runs([load_rows(p) for p in fresh_paths],
                                  load_rows(base_path),
                                  args.threshold, args.min_us)
    print("\n".join(report))
    if failures:
        print(f"\nFAIL: {len(failures)} row(s) regressed past "
              f"{args.threshold}x in every fresh run: "
              f"{', '.join(failures)}")
        return 1
    print("\nperf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
