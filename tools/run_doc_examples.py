#!/usr/bin/env python
"""Execute every fenced ```python block in the given markdown files.

The docs CI job: README.md and docs/API.md promise that their examples
run, so this script extracts each fenced Python block and executes it in
a fresh subprocess (blocks are self-contained by convention).  A block
that exits nonzero fails the job with the file, line number, and output.

Environment per block: ``PYTHONPATH=src`` (src-layout import) and a
2-device host platform (``--xla_force_host_platform_device_count=2``
prepended to ``XLA_FLAGS``) so the distributed examples exercise a real
multi-shard mesh even on CPU CI.

    python tools/run_doc_examples.py [files...]     # default: README.md docs/API.md
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FILES = ("README.md", "docs/API.md")
_FENCE = re.compile(r"^```python\s*$")
_CLOSE = re.compile(r"^```\s*$")


def extract_blocks(path: pathlib.Path):
    """Yield (start_lineno, code) for every ```python fenced block."""
    lines = path.read_text().splitlines()
    block: list[str] | None = None
    start = 0
    for i, line in enumerate(lines, 1):
        if block is None:
            if _FENCE.match(line):
                block, start = [], i + 1
        elif _CLOSE.match(line):
            yield start, "\n".join(block) + "\n"
            block = None
        else:
            block.append(line)
    if block is not None:
        raise SystemExit(f"{path}: unterminated ```python block at "
                         f"line {start - 1}")


def run_block(path: pathlib.Path, lineno: int, code: str) -> bool:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", "")).strip()
    t0 = time.time()
    tag = f"{path.relative_to(REPO)}:{lineno}"
    try:
        proc = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                              env=env, capture_output=True, text=True,
                              timeout=600)
    except subprocess.TimeoutExpired as e:
        # report a hung block like any other failure and keep going
        print(f"FAIL {tag} (timeout after {e.timeout:.0f}s)")
        print("-" * 60)
        print(code)
        print("-" * 60)
        for stream, sink in ((e.stdout, sys.stdout), (e.stderr, sys.stderr)):
            if stream:
                sink.write(stream if isinstance(stream, str)
                           else stream.decode(errors="replace"))
        return False
    dt = time.time() - t0
    if proc.returncode != 0:
        print(f"FAIL {tag} ({dt:.1f}s)")
        print("-" * 60)
        print(code)
        print("-" * 60)
        sys.stdout.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        return False
    out = proc.stdout.strip().splitlines()
    trailer = f"  | {out[-1]}" if out else ""
    print(f"ok   {tag} ({dt:.1f}s){trailer}")
    return True


def main(argv=None) -> int:
    files = [pathlib.Path(f) for f in (argv or sys.argv[1:])] or \
        [REPO / f for f in DEFAULT_FILES]
    n_blocks = failures = 0
    for f in files:
        f = f if f.is_absolute() else REPO / f
        for lineno, code in extract_blocks(f):
            n_blocks += 1
            if not run_block(f, lineno, code):
                failures += 1
    print(f"{n_blocks - failures}/{n_blocks} doc examples passed")
    return 1 if failures or not n_blocks else 0


if __name__ == "__main__":
    raise SystemExit(main())
