#!/usr/bin/env python
"""Gating entry point for the static contract checker.

Thin wrapper over ``python -m repro.verify`` that works from a bare
checkout (adds ``src/`` to ``sys.path``), so CI and pre-commit hooks can
run ``python tools/spgemm_lint.py --all --json verify_report.json``
without an editable install.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.verify.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] + ["--root", str(ROOT)]
                  if "--root" not in sys.argv else sys.argv[1:]))
